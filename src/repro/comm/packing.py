"""Bucketed flat-buffer packing for the CHOCO gossip exchange.

The wire format of the paper's Algorithm-2 messages q_i = Q(x_i - x_hat_i):
payload layout, wire-bit accounting, and the packed-vs-per-leaf launch
audit live in EXPERIMENTS.md §Perf A and §Perf D.

The per-leaf gossip path compresses and ppermutes every pytree leaf in a
Python loop — for a transformer that is dozens of top-k launches and
collective-permutes per round, exactly the launch-overhead regime Koloskova
et al. (2019/2020) say must be amortized for compressed gossip to win at
scale.  This module packs the whole parameter pytree into a small number of
dtype-homogeneous flat *buckets*:

  * the packing spec (bucket layout + per-leaf slots) is computed once from
    the pytree structure and reused every round — it depends only on static
    shape/dtype metadata, so it can be built from tracers or eval_shape;
  * leaf segments inside compressed buckets are padded to `align`-element
    boundaries (a multiple of the 128-lane TPU tile).  Blockwise compression
    commutes with block-aligned concatenation, so compressing a packed
    bucket ONCE (one Pallas/top-k launch) is bit-for-bit identical to
    compressing each leaf separately with the same blockwise operator;
  * tiny leaves (norm scales, biases) can be routed to an *exact* bucket —
    the per-leaf path's ``exact_small_leaves`` branch becomes a bucket
    routing rule — and ship uncompressed as one dense buffer;
  * each bucket emits ONE static-shape wire payload, so the whole exchange
    is a handful of collective-permutes per neighbour instead of one (or
    two) per leaf.

Layout rules: buckets are keyed by (dtype, exact?, route) and split when
they would exceed ``max_bucket_elems`` (bounds top_k width and latency).  A
single leaf larger than the cap cannot be split — it gets a dedicated
bucket, and the TopK path falls back to the legacy row-blockwise selection
so no individual top_k ever exceeds ``MAX_BUCKET_ELEMS`` lanes (int32-safe
within-block indices).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import (BlockTopK, Compressor, DensePayload,
                                    Identity, PackedQuantPayload,
                                    PackedSparsePayload, QSGD, RandK,
                                    SignNorm, SparsePayload, TopK, _resolve_k)
from repro.kernels import dispatch as kdispatch

LANES = 128
#: default cap on bucket size — same constant the per-leaf path used for
#: row-blockwise chunking of huge leaves (int32-safe top_k, bounded latency)
MAX_BUCKET_ELEMS = 1 << 22


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside the packed buffers."""
    leaf: int                  # index in tree_flatten order
    bucket: int
    offset: int                # start offset inside the bucket buffer
    size: int                  # logical element count
    shape: Tuple[int, ...]
    dtype: Any


@dataclasses.dataclass(frozen=True)
class Bucket:
    index: int
    dtype: Any                 # buffer dtype (the EF-state dtype of its leaves)
    exact: bool                # ships uncompressed (DensePayload)
    size: int                  # padded buffer length
    logical: int               # sum of leaf sizes (excludes padding)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    treedef: Any
    slots: Tuple[LeafSlot, ...]
    buckets: Tuple[Bucket, ...]
    align: int                 # segment alignment inside compressed buckets

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def bucket_slots(self, b: int) -> List[LeafSlot]:
        return [s for s in self.slots if s.bucket == b]


def _round_up(n: int, unit: int) -> int:
    return -(-n // unit) * unit


def make_bucket_spec(tree, *, align: int = LANES,
                     exact_small_leaves: bool = False,
                     small_leaf_threshold: int = 8_192,
                     max_bucket_elems: int = MAX_BUCKET_ELEMS,
                     routes: Optional[Sequence] = None) -> BucketSpec:
    """Build the packing spec from a pytree of arrays / ShapeDtypeStructs.

    Only .shape/.dtype are read, so `tree` may hold tracers or eval_shape
    results; the spec is pure static metadata, computed once and reused.

    routes: optional per-leaf hashable routing keys (tree_flatten order).
    Leaves only share a bucket when their route matches.  The gossip layer
    routes by each leaf's replication signature over non-gossip mesh axes:
    mixing a model-SHARDED leaf and a model-REPLICATED leaf in one bucket
    would make bucket-level selection (top-k order, qsgd norm) differ across
    model shards and silently de-replicate the replicated leaf.
    """
    assert align % LANES == 0, "segment alignment must be a lane multiple"
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if routes is not None:
        assert len(routes) == len(leaves), (len(routes), len(leaves))
    # open bucket per (dtype, exact, route) key: [bucket_index, cursor]
    open_buckets = {}
    slots: List[LeafSlot] = []
    buckets: List[List] = []   # [dtype, exact, cursor(=padded size), logical]
    for i, leaf in enumerate(leaves):
        size = 1
        for dim in leaf.shape:
            size *= dim
        dtype = jnp.dtype(leaf.dtype)
        exact = bool(exact_small_leaves and size <= small_leaf_threshold)
        seg = size if exact else _round_up(size, align)
        key = (dtype.name, exact, None if routes is None else routes[i])
        b = open_buckets.get(key)
        if b is None or (buckets[b][2] + seg > max_bucket_elems
                         and buckets[b][2] > 0):
            b = len(buckets)
            buckets.append([dtype, exact, 0, 0])
            open_buckets[key] = b
        slots.append(LeafSlot(leaf=i, bucket=b, offset=buckets[b][2],
                              size=size, shape=tuple(leaf.shape), dtype=dtype))
        buckets[b][2] += seg
        buckets[b][3] += size
    return BucketSpec(
        treedef=treedef,
        slots=tuple(slots),
        buckets=tuple(Bucket(index=i, dtype=d, exact=e, size=c, logical=l)
                      for i, (d, e, c, l) in enumerate(buckets)),
        align=align)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack_leaves(spec: BucketSpec, flat_leaves: Sequence[jax.Array]
                ) -> List[jax.Array]:
    """Flat per-leaf vectors -> one padded flat buffer per bucket.

    One concatenate per bucket; segment padding is zero (blockwise top-k
    never prefers a zero over a real coordinate, qsgd codes zeros to zero).
    """
    parts: List[List[jax.Array]] = [[] for _ in spec.buckets]
    cursors = [0] * len(spec.buckets)
    for slot in spec.slots:
        seg = flat_leaves[slot.leaf].ravel().astype(spec.buckets[slot.bucket].dtype)
        pad = (slot.offset - cursors[slot.bucket])
        if pad:
            parts[slot.bucket].append(
                jnp.zeros((pad,), spec.buckets[slot.bucket].dtype))
        parts[slot.bucket].append(seg)
        cursors[slot.bucket] = slot.offset + slot.size
    bufs = []
    for b, bucket in enumerate(spec.buckets):
        tail = bucket.size - cursors[b]
        if tail:
            parts[b].append(jnp.zeros((tail,), bucket.dtype))
        bufs.append(jnp.concatenate(parts[b]) if len(parts[b]) > 1
                    else parts[b][0])
    return bufs


def unpack_leaves(spec: BucketSpec, bufs: Sequence[jax.Array]
                  ) -> List[jax.Array]:
    """Bucket buffers -> flat per-leaf vectors (in slot dtype, slot order)."""
    out: List[Optional[jax.Array]] = [None] * len(spec.slots)
    for slot in spec.slots:
        seg = jax.lax.dynamic_slice_in_dim(bufs[slot.bucket], slot.offset,
                                           slot.size)
        out[slot.leaf] = seg.astype(slot.dtype)
    return out


def pack_pytree(spec: BucketSpec, tree) -> List[jax.Array]:
    """Pack a whole pytree (matching the spec's treedef) into the bucket
    buffers — ``pack_leaves`` plus the structure check."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    assert treedef == spec.treedef, "pytree structure does not match the spec"
    return pack_leaves(spec, leaves)


def unpack_pytree(spec: BucketSpec, bufs: Sequence[jax.Array]):
    """Inverse of :func:`pack_pytree`: bucket buffers back to a pytree with
    the spec's structure and per-leaf shapes/dtypes."""
    flats = unpack_leaves(spec, bufs)
    leaves = [f.reshape(s.shape) for f, s in zip(flats, sorted(
        spec.slots, key=lambda sl: sl.leaf))]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# per-bucket compression
# ---------------------------------------------------------------------------

def _slot_budget(compressor, slots, bucket: Bucket) -> int:
    """Sparse coordinate budget: resolved PER SLOT and summed, so the packed
    exchange keeps exactly the per-leaf path's budget (an absolute k means
    k per leaf, not k per bucket; fractions sum to the same total)."""
    if slots:
        k = sum(_resolve_k(s.size, compressor.k, compressor.fraction)
                for s in slots)
    else:
        k = _resolve_k(bucket.logical, compressor.k, compressor.fraction)
    return min(k, bucket.logical)


def _logical_positions(slots, bucket: Bucket) -> jax.Array:
    """Padded-buffer indices of the bucket's logical coordinates."""
    if not slots:
        return jnp.arange(bucket.logical)
    return jnp.concatenate([s.offset + jnp.arange(s.size) for s in slots])


def compress_bucket(compressor: Compressor, key, buf: jax.Array,
                    bucket: Bucket,
                    slots: Optional[Sequence[LeafSlot]] = None,
                    *, backend: str = "jnp"):
    """Compress one packed bucket buffer into a single wire payload.

    slots: the bucket's LeafSlots — lets sparse operators resolve their
    coordinate budget per leaf (matching the per-leaf path) and sample over
    logical positions only (never the alignment padding).

    backend: the resolved kernel backend ("jnp"/"pallas",
    kernels/dispatch.py) for the elementwise quantize math.  Only QSGD
    and SignNorm have a fused kernel; both backends are bit-exact, so
    the wire payload is identical either way.

    Dispatches to the block-kernel paths (one launch per bucket):
      * BlockTopK  -> batched blockwise top-k  (kernels/ops.block_topk_select)
      * TopK       -> one global lax.top_k with k resolved from the bucket's
                      logical size (sum of leaf sizes, padding excluded)
      * RandK      -> per-slot budget, sampled over logical positions only
      * QSGD       -> the int8/int16 quantize codes of kernels/qsgd.py
                      (fused pallas launch or the ref-exact jnp inline)
                      + a scale using the *logical* dim's tau
      * SignNorm   -> int8 sign codes + logical-mean scale
      * Identity / exact buckets -> the dense buffer itself
    Anything else falls back to the compressor's own flat compress() over
    the padded buffer.
    """
    if bucket.exact or isinstance(compressor, Identity):
        return DensePayload(buf)
    if isinstance(compressor, BlockTopK):
        return compressor.compress(key, buf)
    if isinstance(compressor, RandK):
        k = _slot_budget(compressor, slots, bucket)
        # sample over logical coordinates only — uniform sampling of the
        # padded buffer would ship guaranteed-zero padding positions
        logical = _logical_positions(slots, bucket)
        idx = logical[jax.random.permutation(key, bucket.logical)[:k]]
        vals = buf[idx]
        if compressor.rescale:
            vals = vals * (bucket.logical / k)
        return SparsePayload(vals, idx.astype(jnp.int32), buf.size)
    if isinstance(compressor, TopK):
        k = _slot_budget(compressor, slots, bucket)
        if buf.size > MAX_BUCKET_ELEMS:
            # oversized single-leaf bucket (spec cannot split a leaf): fall
            # back to the legacy row-blockwise selection — bounded top_k
            # width, int32-safe within-block indices
            from repro.kernels.ops import block_topk_select
            n_blocks = -(-buf.size // MAX_BUCKET_ELEMS)
            kb = max(1, -(-k // n_blocks))
            vals, idx = block_topk_select(buf, kb, block=MAX_BUCKET_ELEMS)
            return PackedSparsePayload(vals, idx, buf.size, MAX_BUCKET_ELEMS)
        _, idx = jax.lax.top_k(jnp.abs(buf), k)
        return SparsePayload(buf[idx], idx.astype(jnp.int32), buf.size)
    if isinstance(compressor, QSGD):
        # elementwise codes via kernels/dispatch.py (fused pallas launch
        # or the bit-exact jnp inline); the norm reduction stays here, on
        # the unpadded buffer, so both backends share it exactly.  Padding
        # quantizes to zero codes (|0|*s/norm + xi < 1 floors to 0).
        s = compressor.s
        x32 = buf.astype(jnp.float32)
        xi = jax.random.uniform(key, buf.shape)
        norm = jnp.sqrt(jnp.sum(jnp.square(x32)))
        inv_norm = jnp.where(norm == 0, 0.0, 1.0 / norm)
        # levels naturally bound by s (|x|/norm <= 1); int16 above s=127
        # exactly like QSGD.compress — int8 would silently halve large coords
        codes = kdispatch.qsgd_codes(x32, xi, inv_norm, s, backend=backend)
        # scale with the logical dimension's tau: zero padding contributes
        # nothing to the norm but would inflate tau if counted in d
        tau = compressor._tau(bucket.logical) if compressor.rescale else 1.0
        scale = norm / (s * tau)
        bits = int(math.ceil(math.log2(2 * s + 1))) + 1
        return PackedQuantPayload(codes, scale.astype(jnp.float32), bits,
                                  dim=bucket.size, logical=bucket.logical)
    if isinstance(compressor, SignNorm):
        x32 = buf.astype(jnp.float32)
        scale = jnp.sum(jnp.abs(x32)) / bucket.logical
        return PackedQuantPayload(kdispatch.sign_codes(x32, backend=backend),
                                  scale.astype(jnp.float32), 1,
                                  dim=bucket.size, logical=bucket.logical)
    return compressor.compress(key, buf)


def bucket_dense(payload, bucket: Bucket) -> jax.Array:
    """Dense q for one bucket, padded back to the full buffer length."""
    q = payload.dense()
    if q.size < bucket.size:
        q = jnp.pad(q, (0, bucket.size - q.size))
    return q[: bucket.size].astype(bucket.dtype)


def compress_bufs(compressor: Compressor, key, spec: BucketSpec,
                  bufs: Sequence[jax.Array], *, backend: str = "jnp"):
    """Compress already-packed bucket buffers.  Returns (payloads, q_bufs):
    one wire payload per bucket plus its dense q padded back to the full
    buffer length — the bucket-space twin of :func:`compress_packed`, used
    directly by the fused EF path (which keeps state in bucket space).

    Key salting is per bucket (``fold_in(key, bucket.index)``) for
    stochastic compressors on compressed buckets — identical to
    :func:`compress_packed`, so both paths draw the same wire bits.
    """
    payloads = []
    for bucket, buf in zip(spec.buckets, bufs):
        bkey = (jax.random.fold_in(key, bucket.index)
                if (compressor.stochastic and key is not None
                    and not bucket.exact) else None)
        payloads.append(compress_bucket(compressor, bkey, buf, bucket,
                                        spec.bucket_slots(bucket.index),
                                        backend=backend))
    q_bufs = [bucket_dense(p, b) for p, b in zip(payloads, spec.buckets)]
    return payloads, q_bufs


def compress_packed(compressor: Compressor, key, spec: BucketSpec,
                    flat_leaves: Sequence[jax.Array], *,
                    backend: str = "jnp"):
    """pack -> compress (once per bucket).  Returns (payloads, q_leaves):
    one payload per bucket plus the dense per-leaf q (for the local EF
    update), so local and remote integration use the SAME quantized values.
    """
    bufs = pack_leaves(spec, flat_leaves)
    payloads, q_bufs = compress_bufs(compressor, key, spec, bufs,
                                     backend=backend)
    q_leaves = unpack_leaves(spec, q_bufs)
    return payloads, q_leaves


def payloads_dense_leaves(spec: BucketSpec, payloads) -> List[jax.Array]:
    """Received payloads -> flat per-leaf dense q (one unpack per exchange)."""
    return unpack_leaves(
        spec, [bucket_dense(p, b) for p, b in zip(payloads, spec.buckets)])


def bucket_omegas(spec: BucketSpec, compressor: Compressor) -> List[float]:
    """Per-bucket Assumption-1 omega, in bucket order.  Each bucket is
    compressed independently, so each is its own CHOCO-Gossip instance with
    its own contraction — this is what the per-bucket Theorem-2 stepsize
    (core.choco_gossip.GammaSpec) is evaluated against.  Exact buckets ship
    uncompressed (omega = 1); sparse coordinate budgets resolve per slot,
    exactly as compress_bucket does."""
    omegas = []
    for b in spec.buckets:
        if b.exact or isinstance(compressor, Identity):
            omegas.append(1.0)
        elif isinstance(compressor, (TopK, RandK)):
            k = _slot_budget(compressor, spec.bucket_slots(b.index), b)
            omegas.append(k / b.logical)
        else:
            omegas.append(compressor.omega(b.logical))
    return omegas


def bucket_omega_worst(spec: BucketSpec, compressor: Compressor) -> float:
    """Worst-case (smallest) Assumption-1 omega over the spec's compressed
    buckets.  A single global consensus stepsize is governed by the
    slowest-contracting bucket, so this is the omega it must be computed
    from (not a fixed representative dimension).  Exact buckets ship
    uncompressed (omega = 1) and never bind — unless every bucket is exact,
    in which case omega is exactly 1."""
    omegas = [w for b, w in zip(spec.buckets, bucket_omegas(spec, compressor))
              if not (b.exact or isinstance(compressor, Identity))]
    return min(omegas) if omegas else 1.0


def bucket_wire_bits(spec: BucketSpec, compressor: Compressor) -> List[int]:
    """Analytic bits-on-the-wire per bucket, in bucket order — the
    per-bucket twin of :func:`bucket_omegas`, consumed by the telemetry
    run header (``obs/metrics.py::bucket_telemetry``)."""
    bits = []
    for b in spec.buckets:
        if b.exact:
            bits.append(b.logical * jnp.dtype(b.dtype).itemsize * 8)
        elif isinstance(compressor, (TopK, RandK)):
            # mirrors compress_bucket: coordinate budget resolved per slot
            bits.append(sum(compressor.wire_bits(s.size)
                            for s in spec.bucket_slots(b.index)))
        elif isinstance(compressor, (BlockTopK, QSGD, SignNorm)):
            bits.append(compressor.wire_bits(b.logical))
        else:
            bits.append(compressor.wire_bits(b.size))
    return [int(x) for x in bits]


def packed_wire_bits(spec: BucketSpec, compressor: Compressor) -> int:
    """Analytic bits-on-the-wire of one packed exchange (all buckets)."""
    return sum(bucket_wire_bits(spec, compressor))
