"""Pipelined CHOCO gossip: hide the compressed exchange behind the backward pass.

Audited in EXPERIMENTS.md §Perf H (HLO overlap audit, benchmarks/
bench_overlap.py); distributed acceptance in tests/test_pipelined.py.

Every synchronous engine before this module puts the exchange on the
critical path: the payload is compressed from the POST-gradient iterate
``x_half``, so the collective cannot start until the backward pass has
finished, and the update cannot finish until the collective lands.  All the
wire bytes compression saves still serialize behind the matmuls.

This engine reorders one thing: the payload is compressed from the iterate
*before* the concurrent gradient is applied, and the received payload is
integrated into the update of the *next* round.  Per node i, per round t:

    q_t      = Q(x_t - x_hat_t)          compress BEFORE the update
    x_{t+1}  = x_t + gamma (s_t - x_hat_t)   <- round t-1's payload
    x_hat_{t+1} = x_hat_t + q_t
    s_{t+1}  = s_t + sum_j w_ij q_{t,j}      <- lands in the t+1 update

Inside the trainer's step function the ppermute of ``q_t`` therefore has NO
consumer in the current x-update — its result only feeds the carried state
``s`` — so the collective's start/done pair is free of any data dependency
on the forward/backward compute and XLA may schedule the transfer
concurrently with the gradient matmuls (the property bench_overlap.py
audits in the compiled HLO).  The wire schedule is byte-for-byte the static
engine's: same payloads, same permute rounds, zero extra collectives.

Why this is principled rather than a heuristic: the recursion above is
exactly PR 5's bounded-staleness algebra with a DETERMINISTIC delay of 1 on
every edge (``StalenessProcess(delay_probs=(0, 1))`` — see
:func:`pipeline_delay_process`).  The stale pair the update reads,
``(s_t, x_hat_t)``, is the depth-1 ring reconstruction
``(S_r - ring_r[0], x_hat - own_ring[0])`` summed over rounds; because the
delay is uniform and every round ships every step, the rings collapse into
the carry itself — the carry IS the stale snapshot and the freshly
integrated ``(s_{t+1}, x_hat_{t+1})`` is its double buffer.  No replica
trees, no ring state: the TrainState layout is identical to the static
engine's, which is what keeps old checkpoints structurally restorable.

Theorem-2 stepsize: gamma is re-derived from the tau=1 delay surrogate —
(delta, beta) from the delay-averaged mixing matrix
``E_eff = (W + I) / 2`` (freshness phi = E[1/(1+d)] = 1/2 at deterministic
d = 1) and the staleness fold ``omega_eff = omega / (1 + tau) = omega / 2``
from ``StalenessProcess.effective_omega``.  The matrix twin of this engine
is ``core.choco_gossip.choco_pipelined_round``; per-step engine==simulator
parity is asserted in tests/test_pipelined.py.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax

from repro.comm.schedule import GossipSchedule
from repro.core.compression import Compressor, Identity


def pipeline_delay_process(schedule: GossipSchedule):
    """The tau=1 deterministic-delay surrogate the pipelined gamma is
    derived from: a :class:`~repro.comm.async_gossip.StalenessProcess` with
    ``delay_probs = (0, 1)`` (every edge's payload is exactly one round
    late).  The trainer reads ``expected_delta_beta()`` and
    ``effective_omega`` from it; tests drive the delay-expanded stale
    simulator with it to cross-check the compact pipelined recursion."""
    from repro.comm.async_gossip import StalenessProcess
    return StalenessProcess(schedule, max_staleness=1,
                            delay_probs=(0.0, 1.0))


def _pipelined_leaf_updates(leaves_x, leaves_s, leaves_hat, q_leaves,
                            nbr_leaves, w_self, w_nbr, gammas):
    """The pipelined twin of ``gossip._choco_leaf_updates``: x reads the
    PRE-round (s, x_hat) carry, s integrates this round's payload for the
    next update.  Elementwise per leaf; XLA fuses these."""
    new_s, new_x = [], []
    for lx, ls, lhat, qd, nb, g in zip(leaves_x, leaves_s, leaves_hat,
                                       q_leaves, nbr_leaves, gammas):
        new_x.append(lx + g * (ls - lhat).astype(lx.dtype))
        sn = ls + (w_self * qd + w_nbr * nb).reshape(lx.shape).astype(ls.dtype)
        new_s.append(sn)
    return new_s, new_x


def make_pipelined_choco_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                            schedule: GossipSchedule,
                            compressor: Compressor, gamma,
                            gossip_steps: int = 1,
                            exact_small_leaves: bool = False,
                            small_leaf_threshold: int = 8_192,
                            packed: bool = True,
                            pack_align: Optional[int] = None,
                            leaf_routes: Optional[list] = None,
                            kernel_backend: str = "jnp") -> Callable:
    """Returns local_fn(key, x, x_hat, s) -> (x, x_hat, s) for shard_map —
    same signature and state trees as the static choco engine, implementing
    the pipelined recursion of the module docstring ``gossip_steps`` times.

    The send half (compress + x_hat advance) and receive half (schedule
    replay) are the static engine's factored helpers
    (``_packed_self_half`` / ``_per_leaf_self_half`` + ``_neighbor_sum``),
    so packed/per-leaf wire formats, exact-small-leaf routing, and payload
    randomness are byte-identical to the serial exchange; only the update
    ordering differs.  ``gamma`` may be a float or a
    :class:`~repro.core.choco_gossip.GammaSpec` (per-bucket Theorem-2
    stepsizes, packed engine only).

    kernel_backend: resolved backend for the COMPRESS stage only
    (kernels/dispatch.py, threaded to ``_packed_self_half``).  The fused
    bucket-space EF path does not apply here: the pipelined x-update reads
    the PRE-round (s, x_hat) carry, not the freshly integrated pair the
    fused kernel produces, so pallas fuses the quantize and the update
    stays the leaf-wise jnp recursion above (bit-exact either way).
    """
    from repro.comm.gossip import (_LazyFlatIndex, _broadcast_gammas,
                                   _choco_leaf_updates, _flatten_states,
                                   _neighbor_sum, _pack_align,
                                   _packed_self_half, _per_leaf_self_half,
                                   _resolve_leaf_gammas, _self_weight,
                                   _weight_groups)
    from repro.core.choco_gossip import GammaSpec
    del _choco_leaf_updates  # serial-order twin; documented contrast only
    identity = Identity()
    if isinstance(gamma, GammaSpec) and not packed:
        raise ValueError(
            "per-bucket gamma (GammaSpec) requires the packed engine: the "
            "legacy per-leaf exchange has no bucket spec to derive omegas "
            "from — pass a float gamma, or packed=True")
    n = 1
    for sz in sizes:
        n *= sz
    assert schedule.n == n, f"schedule n={schedule.n} != mesh extent {n}"
    assert gossip_steps >= 1
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)
    align = _pack_align(compressor, pack_align)
    groups = _weight_groups(schedule)

    def packed_local_fn(key, x, x_hat, s):
        from repro.comm.packing import (bucket_dense, make_bucket_spec,
                                        unpack_leaves)
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_x, leaves_hat, leaves_s, treedef = _flatten_states(x, x_hat, s)
        spec = make_bucket_spec(leaves_hat, align=align,
                                exact_small_leaves=exact_small_leaves,
                                small_leaf_threshold=small_leaf_threshold,
                                routes=leaf_routes)
        gammas = _broadcast_gammas(
            _resolve_leaf_gammas(gamma, spec, compressor), len(leaves_x))
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, q_leaves, new_hat = _packed_self_half(
                compressor, tkey, leaves_x, leaves_hat, spec,
                backend=kernel_backend)
            if not groups:                     # n == 1: no neighbours
                nbr_leaves, w_nbr = [q * 0.0 for q in q_leaves], 0.0
            else:
                dense_fn = lambda got: [bucket_dense(g, b) for g, b
                                        in zip(got, spec.buckets)]
                nbr_bufs, w_nbr = _neighbor_sum(payloads, groups, axis_arg,
                                                dense_fn, flat_idx)
                nbr_leaves = unpack_leaves(spec, nbr_bufs)
            w_self = _self_weight(schedule, flat_idx)
            leaves_s, leaves_x = _pipelined_leaf_updates(
                leaves_x, leaves_s, leaves_hat, q_leaves, nbr_leaves,
                w_self, w_nbr, gammas)
            leaves_hat = new_hat
        u = treedef.unflatten
        return u(leaves_x), u(leaves_hat), u(leaves_s)

    if packed:
        return packed_local_fn

    def per_leaf_local_fn(key, x, x_hat, s):
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_x, leaves_hat, leaves_s, treedef = _flatten_states(x, x_hat, s)
        gammas = _broadcast_gammas(gamma, len(leaves_x))
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, dense_fns, q_dense, new_hat = _per_leaf_self_half(
                compressor, identity, exact_small_leaves,
                small_leaf_threshold, tkey, leaves_x, leaves_hat)
            if not groups:
                nbr_sum, w_nbr = [q * 0.0 for q in q_dense], 0.0
            else:
                dense_fn = lambda got: [dfn(g) for dfn, g
                                        in zip(dense_fns, got)]
                nbr_sum, w_nbr = _neighbor_sum(payloads, groups, axis_arg,
                                               dense_fn, flat_idx)
            w_self = _self_weight(schedule, flat_idx)
            leaves_s, leaves_x = _pipelined_leaf_updates(
                leaves_x, leaves_s, leaves_hat, q_dense, nbr_sum,
                w_self, w_nbr, gammas)
            leaves_hat = new_hat
        u = treedef.unflatten
        return u(leaves_x), u(leaves_hat), u(leaves_s)

    return per_leaf_local_fn
