"""Topology-compiled gossip schedules.

Compilation determinism, round counts per graph family, and the
launch/byte audits of schedule replay are logged in EXPERIMENTS.md
§Perf E (directed bipartite coloring for push-sum: §Perf F).

The paper's rate depends only on the spectral gap of the mixing matrix W
(Definition 1, Table 1), but a distributed runtime needs W expressed as data
movement: which node sends to which, in how many synchronous rounds, with
what receive weight.  This module is that compiler.  It turns any
``core.topology.Topology`` into a static :class:`GossipSchedule` — a
decomposition

    W = diag(self_weights) + sum_r  weight_r * P_r

where every ``P_r`` is a (partial) permutation matrix, i.e. one
``jax.lax.ppermute`` in the distributed engine (``comm/gossip.py``).  Nodes
absent from a round's permutation receive zeros, which the uniform receive
weight annihilates, so partial rounds stay correct.

Decompositions, by graph family:
  * ring            — 2 shift rounds (+1 / -1); 1 for n == 2
  * torus2d         — 2 shift rounds per grid axis (the old hardcoded
                      pod x data engine, now one compiled schedule)
  * hypercube       — log2(n) dimension-exchange rounds (i <-> i ^ 2^b)
  * fully_connected — n - 1 shift rounds, weight 1/n each
  * anything else   — greedy edge coloring of the support of W: each color
                      class is a matching, shipped as one symmetric-swap
                      permutation round (greedy bound: at most
                      2 * max_degree - 1 rounds; exact for the paper's star
                      and chain)

Everything here is **pure Python + numpy**: compilation reads only static
``Topology`` metadata, never traces jax, and is deterministic — the round
count and permutations depend only on (W, grid).  The schedule is therefore
computed once at trainer-build time and baked into the jitted step as
constants (see ``tests/test_schedule.py::test_schedule_compiler_is_trace_free``).

Time-varying mixing (Koloskova et al. 2020; Toghani & Uribe 2022) is a
sequence of schedules: :func:`compile_schedules` compiles one per topology
and the engine cycles through them across the ``gossip_steps`` consensus
rounds of each SGD step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import DirectedTopology, Topology, _square_factors

#: entries of W below this are treated as structural zeros (no edge)
_EDGE_TOL = 1e-12


@dataclasses.dataclass(frozen=True)
class GossipRound:
    """One synchronous exchange: a ppermute plus per-destination weights.

    ``perm`` is the (src, dst) pair list handed to ``jax.lax.ppermute``
    (flat row-major node ids over the gossip mesh axes).  ``weight`` is the
    uniform receive weight when every destination applies the same one;
    otherwise ``weights[i]`` is node i's receive weight (0 for nodes that
    receive nothing — ppermute hands them zeros anyway)."""
    perm: Tuple[Tuple[int, int], ...]
    weight: Optional[float] = None
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        assert (self.weight is None) != (self.weights is None)


@dataclasses.dataclass(frozen=True)
class GossipSchedule:
    """Static decomposition of one mixing matrix into permutation rounds."""
    name: str
    n: int
    rounds: Tuple[GossipRound, ...]
    self_weights: Tuple[float, ...]          # diag(W), per node
    self_weight: Optional[float] = None      # uniform diag(W), when it is

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def mixing_matrix(self) -> np.ndarray:
        """Reconstruct W from the rounds (used to validate compilation)."""
        W = np.diag(np.asarray(self.self_weights, dtype=np.float64))
        for rnd in self.rounds:
            recv = round_recv_vec(rnd, self.n)
            for src, dst in rnd.perm:
                W[dst, src] += recv[dst]
        return W


def round_recv_vec(rnd: GossipRound, n: int) -> np.ndarray:
    """Per-destination receive weight of one round as an (n,) vector (0 for
    nodes the round's partial permutation skips) — the single extraction of
    the weight-vs-weights round encoding, shared by the stochastic process
    samplers and the push-sum engine."""
    vec = np.zeros(n, dtype=np.float64)
    for src, dst in rnd.perm:
        vec[dst] = rnd.weight if rnd.weight is not None else rnd.weights[dst]
    return vec


def _uniform(values) -> Optional[float]:
    vals = list(values)
    if not vals:
        return None
    first = float(vals[0])
    return first if all(float(v) == first for v in vals) else None


def _make_round(perm, weights_by_dst, n: int) -> GossipRound:
    """Round from explicit per-destination weights; collapses to a uniform
    scalar when every destination weight is identical."""
    w = _uniform(weights_by_dst.values())
    if w is not None:
        return GossipRound(perm=tuple(perm), weight=w)
    vec = [0.0] * n
    for dst, wd in weights_by_dst.items():
        vec[dst] = float(wd)
    return GossipRound(perm=tuple(perm), weights=tuple(vec))


# ---------------------------------------------------------------------------
# structured decompositions
# ---------------------------------------------------------------------------

def _ring_rounds(W: np.ndarray) -> list:
    n = W.shape[0]
    if n < 2:
        return []
    fwd = tuple((i, (i + 1) % n) for i in range(n))
    rounds = [_make_round(fwd, {(i + 1) % n: W[(i + 1) % n, i]
                                for i in range(n)}, n)]
    if n > 2:
        bwd = tuple((i, (i - 1) % n) for i in range(n))
        rounds.append(_make_round(bwd, {(i - 1) % n: W[(i - 1) % n, i]
                                        for i in range(n)}, n))
    return rounds


def _torus_rounds(W: np.ndarray, grid: Tuple[int, int]) -> list:
    """Two shift rounds per grid axis, in the axis order of ``grid`` —
    exactly the data movement of the old hardcoded pod x data engine."""
    rows, cols = grid
    nid = lambda r, c: (r % rows) * cols + (c % cols)
    rounds = []
    for axis_size, step in ((rows, lambda r, c, d: nid(r + d, c)),
                            (cols, lambda r, c, d: nid(r, c + d))):
        if axis_size < 2:
            continue
        for d in (1, -1):
            if axis_size == 2 and d == -1:
                continue          # both directions are the same single edge
            perm = tuple((nid(r, c), step(r, c, d))
                         for r in range(rows) for c in range(cols))
            rounds.append(_make_round(
                perm, {dst: W[dst, src] for src, dst in perm}, rows * cols))
    return rounds


def _hypercube_rounds(W: np.ndarray) -> list:
    n = W.shape[0]
    m = int(np.log2(n))
    rounds = []
    for b in range(m):
        perm = tuple((i, i ^ (1 << b)) for i in range(n))
        rounds.append(_make_round(perm, {dst: W[dst, src]
                                         for src, dst in perm}, n))
    return rounds


def _fully_connected_rounds(W: np.ndarray) -> list:
    n = W.shape[0]
    rounds = []
    for s in range(1, n):
        perm = tuple((i, (i + s) % n) for i in range(n))
        rounds.append(_make_round(perm, {(i + s) % n: W[(i + s) % n, i]
                                         for i in range(n)}, n))
    return rounds


# ---------------------------------------------------------------------------
# general graphs: greedy edge coloring
# ---------------------------------------------------------------------------

def _edge_coloring_rounds(W: np.ndarray) -> list:
    """Proper greedy edge coloring of the support of W.  Every color class
    is a matching; a matching ships as one symmetric-swap permutation (each
    matched node sends to and receives from its partner).  Greedy needs at
    most 2 * max_degree - 1 colors; for the paper's graphs it is exact
    (star: n-1, chain: 2)."""
    n = W.shape[0]
    edges = [(i, j) for i in range(n) for j in range(i + 1, n)
             if abs(W[i, j]) > _EDGE_TOL]
    colors: list = []                       # color -> list of (i, j)
    used = [set() for _ in range(n)]        # node -> colors already incident
    for i, j in edges:
        c = 0
        while c in used[i] or c in used[j]:
            c += 1
        while len(colors) <= c:
            colors.append([])
        colors[c].append((i, j))
        used[i].add(c)
        used[j].add(c)
    rounds = []
    for matching in colors:
        perm, weights = [], {}
        for i, j in matching:
            perm += [(i, j), (j, i)]
            weights[j] = W[j, i]
            weights[i] = W[i, j]
        rounds.append(_make_round(tuple(perm), weights, n))
    return rounds


def _directed_coloring_rounds(A: np.ndarray) -> list:
    """Greedy bipartite edge coloring of a DIRECTED support: each directed
    edge (src -> dst) gets a color unused by src as a sender and by dst as a
    receiver, so every color class is a partial permutation (distinct
    sources, distinct destinations) — one ``lax.ppermute``.  By König's
    theorem an optimal coloring needs max(out_deg, in_deg) colors; greedy
    needs at most out_deg + in_deg - 1."""
    n = A.shape[0]
    edges = [(j, i) for j in range(n) for i in range(n)
             if i != j and abs(A[i, j]) > _EDGE_TOL]
    colors: list = []
    used_src = [set() for _ in range(n)]
    used_dst = [set() for _ in range(n)]
    for src, dst in edges:
        c = 0
        while c in used_src[src] or c in used_dst[dst]:
            c += 1
        while len(colors) <= c:
            colors.append([])
        colors[c].append((src, dst))
        used_src[src].add(c)
        used_dst[dst].add(c)
    rounds = []
    for cls in colors:
        perm = tuple(cls)
        weights = {dst: A[dst, src] for src, dst in cls}
        rounds.append(_make_round(perm, weights, n))
    return rounds


def compile_directed_schedule(topo: DirectedTopology) -> GossipSchedule:
    """Compile a column-stochastic directed A into permutation rounds via
    bipartite edge coloring (König): same GossipSchedule contract as the
    symmetric compiler — A = diag(self_weights) + sum_r weight_r * P_r —
    consumed by the push-sum engine (comm/pushsum.py), never by the
    symmetric CHOCO engines (their row-stochastic averaging diverges on a
    column-stochastic A)."""
    A = np.asarray(topo.A, dtype=np.float64)
    n = A.shape[0]
    rounds = _directed_coloring_rounds(A)
    diag = tuple(float(A[i, i]) for i in range(n))
    sched = GossipSchedule(name=topo.name, n=n, rounds=tuple(rounds),
                           self_weights=diag, self_weight=_uniform(diag))
    err = float(np.max(np.abs(sched.mixing_matrix() - A))) if n else 0.0
    if err > 1e-9:
        raise AssertionError(
            f"directed schedule compilation failed for {topo.name!r} "
            f"(n={n}): reconstruction error {err}")
    return sched


# ---------------------------------------------------------------------------
# compiler
# ---------------------------------------------------------------------------

def compile_schedule(topo: Topology,
                     grid: Optional[Tuple[int, int]] = None) -> GossipSchedule:
    """Compile one Topology into permutation rounds.

    grid: (rows, cols) mapping of node ids onto a 2-d grid — required when a
    ``torus2d`` topology should decompose into axis shifts and the
    factorization differs from ``_square_factors(n)`` (the trainer passes
    the (pod, data) mesh extents).  Every structured decomposition is
    validated against W; on mismatch (e.g. a hand-built W reusing a family
    name) compilation falls back to greedy edge coloring, which is exact by
    construction.
    """
    W = np.asarray(topo.W, dtype=np.float64)
    n = W.shape[0]
    if not np.allclose(W, W.T, atol=1e-10):
        raise ValueError(
            "schedule compiler requires a symmetric W; a directed "
            "(column-stochastic) mixing matrix must go through "
            "compile_directed_schedule + the push-sum engine "
            "(comm/pushsum.py)")

    builders = {
        "ring": lambda: _ring_rounds(W),
        "torus2d": lambda: _torus_rounds(W, grid or _square_factors(n)),
        "hypercube": lambda: _hypercube_rounds(W),
        "fully_connected": lambda: _fully_connected_rounds(W),
    }
    builder = builders.get(topo.name)
    candidates = [builder] if builder is not None else []
    candidates.append(lambda: _edge_coloring_rounds(W))

    diag = tuple(float(W[i, i]) for i in range(n))
    last_err = None
    for build in candidates:
        try:
            rounds = build()
        except (IndexError, ValueError):
            # a hand-built W reusing a family name can break the structured
            # builder's index arithmetic (e.g. "hypercube" with n != 2^m);
            # the edge-coloring fallback is always well-defined
            continue
        sched = GossipSchedule(name=topo.name, n=n, rounds=tuple(rounds),
                               self_weights=diag, self_weight=_uniform(diag))
        err = float(np.max(np.abs(sched.mixing_matrix() - W))) if n else 0.0
        if err <= 1e-9:
            return sched
        last_err = err
    raise AssertionError(
        f"schedule compilation failed for {topo.name!r} (n={n}): "
        f"reconstruction error {last_err}")


def compile_schedules(topos: Sequence[Topology],
                      grid: Optional[Tuple[int, int]] = None
                      ) -> Tuple[GossipSchedule, ...]:
    """Compile a (time-varying) sequence of topologies over the same node
    set; the gossip engine cycles through them round-robin across the
    ``gossip_steps`` consensus rounds of each SGD step."""
    scheds = tuple(compile_schedule(t, grid=grid) for t in topos)
    if not scheds:
        raise ValueError("need at least one topology")
    if len({s.n for s in scheds}) != 1:
        raise ValueError(f"time-varying schedules must share n, "
                         f"got {[s.n for s in scheds]}")
    return scheds
