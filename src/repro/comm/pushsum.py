"""Directed push-sum gossip with compressed payloads (column-stochastic A).

CHOCO-style error feedback (paper Algorithm 2's q/x_hat machinery) on a
directed graph; consensus-rate and in-band weight audits are logged in
EXPERIMENTS.md §Perf F.

The symmetric CHOCO engines average with a row-stochastic, symmetric W; on a
directed graph the natural mixing matrix A is only *column*-stochastic
(every node splits its unit mass over its out-neighbours: 1^T A = 1^T), so
plain neighbour averaging converges to a Perron-weighted point, not the
average.  Push-sum (Kempe et al. 2003; SGP, Assran et al. 2019; compressed:
Toghani & Uribe 2022) fixes the bias by running the SAME recursion on a
scalar weight w (init 1) and de-biasing with the ratio x / w.

Per node i, per gossip round (gamma-lazy, CHOCO-style error feedback):

    q_i      = Q(x_i - x_hat_i)              compressed delta (packed bucket)
    x_hat_i += q_i
    s_i     += a_ii q_i + sum_j a_ij q_j     in-band over the schedule rounds
    x_i     += gamma (s_i - x_hat_i)
    w_i     += gamma (a_ii w_i + sum_j a_ij w_j - w_i)   EXACT (one scalar)

Because 1^T A = 1^T, both 1^T x and 1^T w are conserved exactly, and with
the identity compressor the x-recursion collapses to the classical lazy
push-sum x' = ((1-gamma) I + gamma A) x.  The de-biased estimate z = x / w
converges to the true average even though neither x nor w does.

Wire format: the per-neighbour payload of each round is the packed CHOCO
bucket payload tuple PLUS the node's weight scalar appended in-band — both
ride the same ``lax.ppermute`` call, so the weight costs 4 bytes per
neighbour per round, never an extra collective round.

The schedule is a :func:`~repro.comm.schedule.compile_directed_schedule`
decomposition of A into partial-permutation rounds (bipartite edge
coloring); symmetric schedules also work (a symmetric doubly-stochastic W is
column-stochastic), which is how the engine is cross-checked against CHOCO.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import Compressor
from repro.comm.schedule import GossipSchedule, round_recv_vec
from repro.comm.gossip import (_LazyFlatIndex, _flatten_states, _pack_align,
                               _packed_self_half, _self_weight)


def make_pushsum_schedule_fn(*, axes: Tuple[str, ...], sizes: Tuple[int, ...],
                             schedule: GossipSchedule,
                             compressor: Compressor, gamma: float,
                             gossip_steps: int = 1,
                             pack_align: Optional[int] = None,
                             leaf_routes: Optional[list] = None) -> Callable:
    """Returns local_fn(key, x, x_hat, s, w) -> (x, x_hat, s, w) for
    shard_map — the push-sum twin of the packed CHOCO engine.

    ``w`` is the per-node weight column: global shape (n, 1), local (1, 1)
    inside shard_map.  Rounds are NOT weight-grouped: a directed round's
    receive weight belongs to the *destination* (a_dst,src), and partial
    permutation rounds rarely share one, so each round applies its own
    per-node weight vector.
    """
    n = 1
    for sz in sizes:
        n *= sz
    assert schedule.n == n, f"schedule n={schedule.n} != mesh extent {n}"
    assert gossip_steps >= 1
    axis_arg = axes[0] if len(axes) == 1 else tuple(axes)
    align = _pack_align(compressor, pack_align)
    # per-round per-destination receive weights as f32 rows (R, n)
    recv_rows = [tuple(round_recv_vec(rnd, n)) for rnd in schedule.rounds]

    def local_fn(key, x, x_hat, s, w):
        from repro.comm.packing import (bucket_dense, make_bucket_spec,
                                        unpack_leaves)
        for a in axes:
            key = jax.random.fold_in(key, jax.lax.axis_index(a))
        leaves_x, leaves_hat, leaves_s, treedef = _flatten_states(x, x_hat, s)
        spec = make_bucket_spec(leaves_hat, align=align,
                                routes=leaf_routes)
        flat_idx = _LazyFlatIndex(axes, sizes)
        for t in range(gossip_steps):
            tkey = key if t == 0 else jax.random.fold_in(key, t)
            payloads, q_leaves, new_hat = _packed_self_half(
                compressor, tkey, leaves_x, leaves_hat, spec)
            a_self = _self_weight(schedule, flat_idx)
            # in-band wire unit: (bucket payloads, weight scalar) — one
            # ppermute pytree per round, the scalar rides along
            wire = (payloads, w)
            nbr_bufs = None
            nbr_w = a_self * w
            for rnd, recv in zip(schedule.rounds, recv_rows):
                got_pl, got_w = jax.lax.ppermute(wire, axis_arg,
                                                 list(rnd.perm))
                a_recv = jnp.asarray(recv, jnp.float32)[flat_idx()]
                bufs = [a_recv * bucket_dense(g, b)
                        for g, b in zip(got_pl, spec.buckets)]
                nbr_bufs = bufs if nbr_bufs is None else [
                    acc + b for acc, b in zip(nbr_bufs, bufs)]
                nbr_w = nbr_w + a_recv * got_w
            if nbr_bufs is None:            # n == 1: A = [[1]]
                nbr_leaves = [q * 0.0 for q in q_leaves]
            else:
                nbr_leaves = unpack_leaves(spec, nbr_bufs)
            new_s, new_x = [], []
            for lx, ls, qd, nb, nh in zip(leaves_x, leaves_s, q_leaves,
                                          nbr_leaves, new_hat):
                # s += a_ii q_i + sum_j a_ij q_j  (Algorithm-5 shape, A cols)
                sn = ls + (a_self * qd + nb).reshape(lx.shape).astype(ls.dtype)
                new_s.append(sn)
                new_x.append(lx + gamma * (sn - nh).astype(lx.dtype))
            leaves_s, leaves_x, leaves_hat = new_s, new_x, new_hat
            w = w + gamma * (nbr_w - w).astype(w.dtype)
        unflatten = treedef.unflatten
        return (unflatten(leaves_x), unflatten(leaves_hat),
                unflatten(leaves_s), w)

    return local_fn


def debias(x, w):
    """Push-sum de-biased estimate z = x / w, broadcast over each leaf's
    trailing dims (w is the (n, 1) weight column; leaves carry a leading
    node dim)."""
    def leaf(a):
        wb = w.reshape((w.shape[0],) + (1,) * (a.ndim - 1))
        return (a / wb.astype(a.dtype)).astype(a.dtype)
    return jax.tree.map(leaf, x)
