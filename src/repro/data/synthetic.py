"""Synthetic data pipelines.

Two families:
  * Token streams for LM training (per-node shards; `sorted` vs `shuffled`
    assignment mirrors the paper's hardest/easiest heterogeneity settings).
  * Logistic-regression datasets with the shape/density statistics of the
    paper's *epsilon* (dense d=2000) and *rcv1* (sparse d=47236) benchmarks —
    the container is offline, so the data is generated, not downloaded
    (documented deviation in DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.partition import (dirichlet_class_shares, dirichlet_shards,
                                  mean_tv_distance)


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStream:
    """Synthetic Zipf-distributed token stream, shardable across gossip nodes.

    `heterogeneity`: 0.0 = iid across nodes (randomly shuffled);
    1.0 = fully sorted (each node samples a disjoint vocabulary slice) —
    the paper's `sorted` setting where decentralized averaging matters most.

    `skew_alpha`: when set, per-node vocabulary ownership is drawn from a
    seeded Dirichlet(alpha) over the vocab (``data/partition.py``) instead
    of the hard `heterogeneity` slice mask — alpha -> inf recovers the IID
    Zipf stream, alpha -> 0 recovers near-disjoint `sorted`-style slices.
    Takes precedence over `heterogeneity` when both are given.
    """
    vocab_size: int
    seq_len: int
    batch_per_node: int
    n_nodes: int
    heterogeneity: float = 0.0
    seed: int = 0
    skew_alpha: Optional[float] = None

    def node_probs(self) -> np.ndarray:
        """Per-node token sampling distributions, ``(n_nodes, vocab_size)``.

        Deterministic in the dataclass fields alone (the Dirichlet draw
        uses its own ``default_rng(seed)`` stream, independent of the
        token-sampling stream), so telemetry and tests can recompute the
        exact distributions the iterator samples from.
        """
        V = self.vocab_size
        base_p = 1.0 / np.arange(1, V + 1)
        probs = np.tile(base_p, (self.n_nodes, 1))
        if self.skew_alpha is not None:
            shares = dirichlet_class_shares(
                V, self.n_nodes, self.skew_alpha,
                np.random.default_rng(self.seed))
            probs = probs * (shares.T * self.n_nodes)
        elif self.heterogeneity > 0:
            h = self.heterogeneity
            slice_size = V // self.n_nodes
            for i in range(self.n_nodes):
                mask = np.zeros(V)
                lo = i * slice_size
                # last node absorbs the V % n_nodes remainder so the
                # union of slices always covers the whole vocabulary
                hi = (i + 1) * slice_size if i < self.n_nodes - 1 else V
                mask[lo:hi] = 1.0
                probs[i] = base_p * ((1 - h) + h * V * mask)
        return probs / probs.sum(axis=1, keepdims=True)

    def skew_tv(self) -> float:
        """Mean TV distance of per-node token distributions from their
        average — the host-side source of ``diag/data_skew_tv``."""
        return mean_tv_distance(self.node_probs())

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        probs = self.node_probs()
        V = self.vocab_size
        while True:
            toks = np.empty((self.n_nodes, self.batch_per_node, self.seq_len + 1),
                            np.int32)
            for i in range(self.n_nodes):
                toks[i] = rng.choice(V, size=(self.batch_per_node, self.seq_len + 1),
                                     p=probs[i]).astype(np.int32)
            yield {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


def make_lm_batch_fn(cfg: ModelConfig, seq_len: int, batch_per_node: int,
                     n_nodes: int, heterogeneity: float = 0.0, seed: int = 0,
                     skew_alpha: Optional[float] = None):
    """Returns next_batch() -> pytree of np arrays matching train_batch_specs.

    The returned callable carries a ``skew_tv`` attribute — the mean TV
    divergence of the per-node sampling distributions (0.0 for the audio
    family, whose synthetic frames are IID by construction).
    """
    if cfg.family == "audio":
        rng = np.random.default_rng(seed)
        fe = cfg.frontend

        def next_batch():
            S = seq_len
            emb = rng.standard_normal(
                (n_nodes, batch_per_node, S, fe.embed_dim)).astype(np.float32)
            tgt = rng.integers(0, cfg.vocab_size,
                               (n_nodes, batch_per_node, S)).astype(np.int32)
            mask = (rng.random((n_nodes, batch_per_node, S)) < 0.08).astype(np.float32)
            return {"frame_embeds": emb, "targets": tgt, "mask": mask}
        next_batch.skew_tv = 0.0
        return next_batch

    if cfg.family == "vlm":
        rng = np.random.default_rng(seed)
        fe = cfg.frontend
        text = seq_len - fe.n_tokens
        ts = TokenStream(cfg.vocab_size, text - 1, batch_per_node,
                         n_nodes, heterogeneity, seed, skew_alpha)
        stream = iter(ts)

        def next_batch():
            b = next(stream)
            emb = rng.standard_normal(
                (n_nodes, batch_per_node, fe.n_tokens, fe.embed_dim)).astype(np.float32)
            return {"patch_embeds": emb,
                    "tokens": np.concatenate([b["tokens"], b["labels"][..., -1:]], -1),
                    "labels": np.concatenate([b["labels"], b["labels"][..., -1:]], -1)}
        next_batch.skew_tv = ts.skew_tv()
        return next_batch

    ts = TokenStream(cfg.vocab_size, seq_len, batch_per_node,
                     n_nodes, heterogeneity, seed, skew_alpha)
    stream = iter(ts)

    def next_batch():
        return next(stream)
    next_batch.skew_tv = ts.skew_tv()
    return next_batch


# ---------------------------------------------------------------------------
# logistic regression (paper §5.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    A: jax.Array          # (m, d) features
    b: jax.Array          # (m,) labels in {-1, +1}
    node_index: jax.Array  # (n_nodes, m_per_node) sample ids per node
    reg: float

    @property
    def d(self) -> int:
        return self.A.shape[1]

    def full_loss(self, x):
        z = self.b * (self.A @ x)
        return jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * self.reg * jnp.sum(x * x)

    def make_grad_fn(self, batch_size: int = 1):
        """grad_fn(x_row, node_id, key) — samples a minibatch from the node's
        shard (matches Algorithm 2 line 2)."""
        A, b, idx = self.A, self.b, self.node_index
        m_per = idx.shape[1]
        reg = self.reg

        def grad_fn(x, node, key):
            j = jax.random.randint(key, (batch_size,), 0, m_per)
            rows = idx[node, j]
            a = A[rows]                                   # (bs, d)
            bb = b[rows]
            z = bb * (a @ x)
            g = -(bb * jax.nn.sigmoid(-z))[:, None] * a   # dlog1p(exp(-z))/dx
            return jnp.mean(g, axis=0) + reg * x
        return grad_fn


def make_logreg(name: str, n_nodes: int, *, sorted_assignment: bool = False,
                seed: int = 0, m: Optional[int] = None,
                d: Optional[int] = None,
                skew_alpha: Optional[float] = None) -> LogRegProblem:
    """Synthetic stand-ins matched to the paper's dataset statistics:
    epsilon: m=400k (reduced default 8k), d=2000, dense.
    rcv1:    m=20242 (reduced default 8k), d=47236 (reduced 4724), 0.15% dense.

    ``skew_alpha`` replaces the binary sorted/shuffled assignment with a
    Dirichlet(alpha) shard over the binary labels (``data/partition.py``):
    alpha -> inf recovers the shuffled (IID) split, alpha -> 0 the sorted
    (label-disjoint) split.  Mutually exclusive with ``sorted_assignment``.
    """
    rng = np.random.default_rng(seed)
    if name == "epsilon":
        m = m or 8_000
        d = d or 2_000
        density = 1.0
    elif name == "rcv1":
        m = m or 8_000
        d = d or 4_724
        density = 0.0015 * 10       # keep ~7 nnz/row at reduced d
    else:
        raise ValueError(name)
    # w_true scaled so margins a_i . w are O(3) after row normalisation
    w_true = rng.standard_normal(d) * 3.0
    A = rng.standard_normal((m, d)).astype(np.float32)
    if density < 1.0:
        A *= (rng.random((m, d)) < density)
        A *= 1.0 / np.sqrt(max(density, 1e-6))
    A /= np.maximum(np.linalg.norm(A, axis=1, keepdims=True), 1e-8)   # row-normalised
    logits = A @ w_true + 0.3 * rng.standard_normal(m)
    b = np.where(logits > 0, 1.0, -1.0).astype(np.float32)

    m_per = m // n_nodes
    if skew_alpha is not None:
        if sorted_assignment:
            raise ValueError("skew_alpha and sorted_assignment are "
                             "mutually exclusive")
        node_index = dirichlet_shards(b.astype(np.int64), n_nodes,
                                      skew_alpha, seed=seed)
    else:
        order = np.argsort(b) if sorted_assignment else rng.permutation(m)
        node_index = order[: m_per * n_nodes].reshape(n_nodes, m_per)
    return LogRegProblem(A=jnp.asarray(A), b=jnp.asarray(b),
                         node_index=jnp.asarray(node_index), reg=1.0 / m)
