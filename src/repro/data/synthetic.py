"""Synthetic data pipelines.

Two families:
  * Token streams for LM training (per-node shards; `sorted` vs `shuffled`
    assignment mirrors the paper's hardest/easiest heterogeneity settings).
  * Logistic-regression datasets with the shape/density statistics of the
    paper's *epsilon* (dense d=2000) and *rcv1* (sparse d=47236) benchmarks —
    the container is offline, so the data is generated, not downloaded
    (documented deviation in DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStream:
    """Synthetic Zipf-distributed token stream, shardable across gossip nodes.

    `heterogeneity`: 0.0 = iid across nodes (randomly shuffled);
    1.0 = fully sorted (each node samples a disjoint vocabulary slice) —
    the paper's `sorted` setting where decentralized averaging matters most.
    """
    vocab_size: int
    seq_len: int
    batch_per_node: int
    n_nodes: int
    heterogeneity: float = 0.0
    seed: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        ranks = np.arange(1, V + 1)
        base_p = 1.0 / ranks
        slice_size = V // self.n_nodes
        while True:
            toks = np.empty((self.n_nodes, self.batch_per_node, self.seq_len + 1),
                            np.int32)
            for i in range(self.n_nodes):
                p = base_p.copy()
                if self.heterogeneity > 0:
                    mask = np.zeros(V)
                    mask[i * slice_size:(i + 1) * slice_size] = 1.0
                    p = p * ((1 - self.heterogeneity) + self.heterogeneity * V * mask)
                p = p / p.sum()
                toks[i] = rng.choice(V, size=(self.batch_per_node, self.seq_len + 1),
                                     p=p).astype(np.int32)
            yield {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


def make_lm_batch_fn(cfg: ModelConfig, seq_len: int, batch_per_node: int,
                     n_nodes: int, heterogeneity: float = 0.0, seed: int = 0):
    """Returns next_batch() -> pytree of np arrays matching train_batch_specs."""
    if cfg.family == "audio":
        rng = np.random.default_rng(seed)
        fe = cfg.frontend

        def next_batch():
            S = seq_len
            emb = rng.standard_normal(
                (n_nodes, batch_per_node, S, fe.embed_dim)).astype(np.float32)
            tgt = rng.integers(0, cfg.vocab_size,
                               (n_nodes, batch_per_node, S)).astype(np.int32)
            mask = (rng.random((n_nodes, batch_per_node, S)) < 0.08).astype(np.float32)
            return {"frame_embeds": emb, "targets": tgt, "mask": mask}
        return next_batch

    if cfg.family == "vlm":
        rng = np.random.default_rng(seed)
        fe = cfg.frontend
        text = seq_len - fe.n_tokens
        stream = iter(TokenStream(cfg.vocab_size, text - 1, batch_per_node,
                                  n_nodes, heterogeneity, seed))

        def next_batch():
            b = next(stream)
            emb = rng.standard_normal(
                (n_nodes, batch_per_node, fe.n_tokens, fe.embed_dim)).astype(np.float32)
            return {"patch_embeds": emb,
                    "tokens": np.concatenate([b["tokens"], b["labels"][..., -1:]], -1),
                    "labels": np.concatenate([b["labels"], b["labels"][..., -1:]], -1)}
        return next_batch

    stream = iter(TokenStream(cfg.vocab_size, seq_len, batch_per_node,
                              n_nodes, heterogeneity, seed))
    return lambda: next(stream)


# ---------------------------------------------------------------------------
# logistic regression (paper §5.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    A: jax.Array          # (m, d) features
    b: jax.Array          # (m,) labels in {-1, +1}
    node_index: jax.Array  # (n_nodes, m_per_node) sample ids per node
    reg: float

    @property
    def d(self) -> int:
        return self.A.shape[1]

    def full_loss(self, x):
        z = self.b * (self.A @ x)
        return jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * self.reg * jnp.sum(x * x)

    def make_grad_fn(self, batch_size: int = 1):
        """grad_fn(x_row, node_id, key) — samples a minibatch from the node's
        shard (matches Algorithm 2 line 2)."""
        A, b, idx = self.A, self.b, self.node_index
        m_per = idx.shape[1]
        reg = self.reg

        def grad_fn(x, node, key):
            j = jax.random.randint(key, (batch_size,), 0, m_per)
            rows = idx[node, j]
            a = A[rows]                                   # (bs, d)
            bb = b[rows]
            z = bb * (a @ x)
            g = -(bb * jax.nn.sigmoid(-z))[:, None] * a   # dlog1p(exp(-z))/dx
            return jnp.mean(g, axis=0) + reg * x
        return grad_fn


def make_logreg(name: str, n_nodes: int, *, sorted_assignment: bool = False,
                seed: int = 0, m: Optional[int] = None,
                d: Optional[int] = None) -> LogRegProblem:
    """Synthetic stand-ins matched to the paper's dataset statistics:
    epsilon: m=400k (reduced default 8k), d=2000, dense.
    rcv1:    m=20242 (reduced default 8k), d=47236 (reduced 4724), 0.15% dense.
    """
    rng = np.random.default_rng(seed)
    if name == "epsilon":
        m = m or 8_000
        d = d or 2_000
        density = 1.0
    elif name == "rcv1":
        m = m or 8_000
        d = d or 4_724
        density = 0.0015 * 10       # keep ~7 nnz/row at reduced d
    else:
        raise ValueError(name)
    # w_true scaled so margins a_i . w are O(3) after row normalisation
    w_true = rng.standard_normal(d) * 3.0
    A = rng.standard_normal((m, d)).astype(np.float32)
    if density < 1.0:
        A *= (rng.random((m, d)) < density)
        A *= 1.0 / np.sqrt(max(density, 1e-6))
    A /= np.maximum(np.linalg.norm(A, axis=1, keepdims=True), 1e-8)   # row-normalised
    logits = A @ w_true + 0.3 * rng.standard_normal(m)
    b = np.where(logits > 0, 1.0, -1.0).astype(np.float32)

    m_per = m // n_nodes
    order = np.argsort(b) if sorted_assignment else rng.permutation(m)
    node_index = order[: m_per * n_nodes].reshape(n_nodes, m_per)
    return LogRegProblem(A=jnp.asarray(A), b=jnp.asarray(b),
                         node_index=jnp.asarray(node_index), reg=1.0 / m)
