"""Seeded Dirichlet(alpha) data partitioner for non-IID scenario sweeps.

The paper's §5.3 experiments bracket data heterogeneity with two endpoints:
``shuffled`` (IID: every node sees every label) and ``sorted`` (maximally
skewed: each node owns a contiguous label range).  *Decentralized Deep
Learning with Arbitrary Communication Compression* (Koloskova et al. 2019)
established the standard interpolation between them: draw each class's
per-node allocation from a symmetric Dirichlet(alpha) and shard class
samples proportionally.

  * alpha -> infinity : every class splits uniformly across nodes (IID /
    ``shuffled`` limit);
  * alpha -> 0        : each class collapses onto one node (``sorted`` /
    disjoint-shard limit).

Everything here is host-side numpy on a ``np.random.default_rng(seed)``
stream, so partitions are bit-reproducible across processes from the seed
alone — the same guarantee the exchange-key sampling in
``comm/stochastic.py`` asserts for topology draws.  The module never
imports jax (the data layer is neither traced nor part of a compiled
step).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def _check_alpha(alpha: float) -> float:
    """Validate a Dirichlet concentration; returns it as float.

    ``alpha`` must be a finite-or-+inf value strictly greater than zero —
    Dirichlet(0) is not a distribution, and negative concentrations are a
    user error the CLI also rejects pre-jax.
    """
    a = float(alpha)
    if not a > 0.0:
        raise ValueError(f"data skew alpha must be > 0, got {alpha!r}")
    return a


def dirichlet_class_shares(
    n_classes: int, n_nodes: int, alpha: float,
    rng: np.random.Generator,
) -> np.ndarray:
    """Per-class node allocation proportions, ``(n_classes, n_nodes)``.

    Row ``c`` is one draw from Dirichlet(alpha * 1_{n_nodes}) — the
    fraction of class ``c``'s samples each node receives.  ``alpha`` may
    be ``inf``, which short-circuits to the exact uniform 1/n allocation
    (numpy's sampler rejects non-finite concentrations).
    """
    a = _check_alpha(alpha)
    if not np.isfinite(a):
        return np.full((n_classes, n_nodes), 1.0 / n_nodes)
    shares = rng.dirichlet(np.full(n_nodes, a), size=n_classes)
    # Guard against degenerate all-zero rows from extreme underflow at
    # tiny alpha: collapse such a class onto one uniformly-drawn node.
    bad = ~np.isfinite(shares.sum(axis=1)) | (shares.sum(axis=1) <= 0)
    for c in np.nonzero(bad)[0]:
        shares[c] = 0.0
        shares[c, rng.integers(n_nodes)] = 1.0
    return shares / shares.sum(axis=1, keepdims=True)


def _largest_remainder_counts(share: np.ndarray, total: int) -> np.ndarray:
    """Integer per-node counts summing to ``total``, proportional to
    ``share`` by largest-remainder rounding."""
    raw = share * total
    base = np.floor(raw).astype(np.int64)
    short = total - int(base.sum())
    if short > 0:
        order = np.argsort(-(raw - base), kind="stable")
        base[order[:short]] += 1
    return base


def dirichlet_shards(
    labels: Sequence[int], n_nodes: int, alpha: float, seed: int = 0,
) -> np.ndarray:
    """Partition sample indices into balanced, disjoint Dirichlet shards.

    Returns an ``(n_nodes, m_per)`` int array of sample indices with
    ``m_per = len(labels) // n_nodes`` — the same balanced shape
    ``make_logreg`` feeds to the per-node gradient oracle.  Per class, the
    (shuffled) sample indices are split across nodes by largest-remainder
    rounding of a Dirichlet(alpha) share row; a final rebalance pass moves
    samples from over-full to under-full nodes (preferring each receiver's
    majority class last, so it perturbs skew as little as possible) to hit
    exactly ``m_per`` everywhere.  Shards are disjoint by construction and
    bit-reproducible from ``seed`` alone.
    """
    a = _check_alpha(alpha)
    labels_arr = np.asarray(labels)
    m = labels_arr.shape[0]
    if n_nodes <= 0:
        raise ValueError(f"n_nodes must be positive, got {n_nodes}")
    m_per = m // n_nodes
    if m_per == 0:
        raise ValueError(f"{m} samples cannot fill {n_nodes} nodes")
    rng = np.random.default_rng(seed)

    classes = np.unique(labels_arr)
    shares = dirichlet_class_shares(len(classes), n_nodes, a, rng)

    per_node: list[list[int]] = [[] for _ in range(n_nodes)]
    for c_i, c in enumerate(classes):
        idx = np.nonzero(labels_arr == c)[0]
        rng.shuffle(idx)
        counts = _largest_remainder_counts(shares[c_i], len(idx))
        offsets = np.concatenate([[0], np.cumsum(counts)])
        for node in range(n_nodes):
            per_node[node].extend(idx[offsets[node]:offsets[node + 1]])

    # Rebalance to exactly m_per per node: donors give their most recently
    # assigned (tail) samples to receivers, so class composition of the
    # bulk of each shard is preserved.
    surplus: list[int] = []
    for node in range(n_nodes):
        extra = len(per_node[node]) - m_per
        if extra > 0:
            surplus.extend(per_node[node][m_per:])
            per_node[node] = per_node[node][:m_per]
    rng.shuffle(surplus_arr := np.asarray(surplus, dtype=np.int64))
    cursor = 0
    for node in range(n_nodes):
        need = m_per - len(per_node[node])
        if need > 0:
            per_node[node].extend(surplus_arr[cursor:cursor + need])
            cursor += need

    out = np.asarray([sorted(p) for p in per_node], dtype=np.int64)
    assert out.shape == (n_nodes, m_per)
    return out


def node_label_distributions(
    labels: Sequence[int], node_index: np.ndarray,
    classes: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Per-node label histograms, ``(n_nodes, n_classes)``, rows sum to 1.

    ``node_index`` is the ``(n_nodes, m_per)`` shard array from
    :func:`dirichlet_shards` (or ``make_logreg``'s sorted/shuffled
    assignment).  ``classes`` defaults to the sorted unique labels.
    """
    labels_arr = np.asarray(labels)
    cls = np.unique(labels_arr) if classes is None else np.asarray(classes)
    out = np.zeros((node_index.shape[0], len(cls)))
    for node in range(node_index.shape[0]):
        node_labels = labels_arr[np.asarray(node_index[node])]
        for c_i, c in enumerate(cls):
            out[node, c_i] = np.mean(node_labels == c)
    return out


def mean_tv_distance(node_probs: np.ndarray) -> float:
    """Mean total-variation distance of per-node distributions from their
    average — the ``diag/data_skew_tv`` scalar.

    0 means IID (every node's label/vocab distribution equals the global
    one); the maximum (approaching 1 as shards become disjoint across many
    nodes) means no node resembles the population.  Input rows must each
    sum to ~1; shape ``(n_nodes, n_classes)``.
    """
    probs = np.asarray(node_probs, dtype=np.float64)
    mean = probs.mean(axis=0, keepdims=True)
    return float(0.5 * np.abs(probs - mean).sum(axis=1).mean())


def data_skew_tv(
    labels: Sequence[int], node_index: np.ndarray,
) -> float:
    """Convenience: mean TV divergence of the shards in ``node_index``
    over ``labels`` — composition of :func:`node_label_distributions`
    and :func:`mean_tv_distance`."""
    return mean_tv_distance(node_label_distributions(labels, node_index))
